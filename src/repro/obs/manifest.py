"""Structured JSONL run manifests for ``benchmarks/run.py``.

Every benchmark invocation appends one *run* to a JSONL manifest file —
a header record (config hash, jax/device info, argv, profiler trace dir),
one record per executed module (runtime, claim outcomes, baseline
comparison results, emitted BENCH file, drained wall-clock spans), and a
summary footer.  Line-oriented JSON means successive invocations (CI runs
every module as its own ``run.py`` call) append to one file, and readers
group records by ``run_id``.

Schema (``"schema": 1`` on every record):

* ``{"record": "run", "run_id", "schema", "argv", "config_hash",
   "jax_version", "backend", "device_count", "device_kind",
   "profile_dir", "started_unix"}``
* ``{"record": "module", "run_id", "schema", "name", "ok", "runtime_s",
   "claims": [{"description", "ok"}], "baseline": [{"metric", "status",
   "note"}], "bench_json", "spans": [{"name", "count", "total_s",
   "mean_s"}], "checkpoints": [{"kind", "directory", "round", ...}],
   "num_rows"}``
* ``{"record": "summary", "run_id", "schema", "ok", "modules",
   "failed", "total_runtime_s"}``

``read_manifest`` round-trips the file; ``runs_in_manifest`` groups by
run.  The schema is pinned by ``tests/test_obs.py``.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 1

# run_id = "<ms-hex>-<pid>-<n>": the counter disambiguates writers created
# within the same millisecond of one process (e.g. back-to-back test runs).
_RUN_COUNTER = itertools.count()

MODULE_RECORD_KEYS = (
    "record", "run_id", "schema", "name", "ok", "runtime_s",
    "claims", "baseline", "bench_json", "spans", "checkpoints", "num_rows",
)
RUN_RECORD_KEYS = (
    "record", "run_id", "schema", "argv", "config_hash", "jax_version",
    "backend", "device_count", "device_kind", "profile_dir", "started_unix",
)
SUMMARY_RECORD_KEYS = (
    "record", "run_id", "schema", "ok", "modules", "failed",
    "total_runtime_s",
)


def config_hash(config: Dict[str, Any]) -> str:
    """Stable short hash of a JSON-serializable config mapping."""
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _device_info() -> Dict[str, Any]:
    try:
        import jax

        devices = jax.devices()
        return {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": len(devices),
            "device_kind": devices[0].device_kind if devices else None,
        }
    except Exception:  # pragma: no cover - jax unavailable/uninitializable
        return {
            "jax_version": None,
            "backend": None,
            "device_count": 0,
            "device_kind": None,
        }


class ManifestWriter:
    """Appends one run's records to a JSONL manifest file.

    Usage (see ``benchmarks/run.py``)::

        mw = ManifestWriter(path, argv=sys.argv[1:], config=vars(args))
        mw.start(profile_dir=args.profile)
        mw.module("fig16_tradeoff", ok=True, runtime_s=3.2, rows=rows, ...)
        mw.summary(ok=True, failed=[])
    """

    def __init__(
        self,
        path: str,
        *,
        argv: Sequence[str] = (),
        config: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.path = path
        self.argv = list(argv)
        self.config = dict(config or {})
        self.run_id = (
            f"{int(time.time() * 1000):x}-{os.getpid()}-{next(_RUN_COUNTER)}"
        )
        self._t0 = time.time()
        self._modules: List[str] = []

    def _write(self, record: Dict[str, Any]) -> None:
        record = dict(record, run_id=self.run_id, schema=SCHEMA_VERSION)
        directory = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(directory, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(record, sort_keys=True, default=str) + "\n")

    def start(self, profile_dir: Optional[str] = None) -> None:
        self._write(
            {
                "record": "run",
                "argv": self.argv,
                "config_hash": config_hash(self.config),
                "profile_dir": profile_dir,
                "started_unix": self._t0,
                **_device_info(),
            }
        )

    def module(
        self,
        name: str,
        *,
        ok: bool,
        runtime_s: float,
        rows: Sequence[Dict[str, Any]] = (),
        baseline: Sequence[Dict[str, Any]] = (),
        bench_json: Optional[str] = None,
        spans: Sequence[Dict[str, Any]] = (),
        checkpoints: Sequence[Dict[str, Any]] = (),
    ) -> None:
        # CLAIM rows (benchmarks.common.claim) carry PASS/FAIL in ``value``
        # and the human-readable description in ``note``.
        claims = [
            {
                "description": str(r.get("note", "")),
                "ok": str(r.get("value")) == "PASS",
            }
            for r in rows
            if r.get("metric") == "CLAIM"
        ]
        self._modules.append(name)
        self._write(
            {
                "record": "module",
                "name": name,
                "ok": bool(ok),
                "runtime_s": float(runtime_s),
                "claims": claims,
                "baseline": list(baseline),
                "bench_json": bench_json,
                "spans": list(spans),
                # drained repro.checkpoint snapshot save/restore events —
                # the preemption audit trail of a checkpointed module.
                "checkpoints": list(checkpoints),
                "num_rows": len(rows),
            }
        )

    def summary(self, *, ok: bool, failed: Sequence[str] = ()) -> None:
        self._write(
            {
                "record": "summary",
                "ok": bool(ok),
                "modules": list(self._modules),
                "failed": list(failed),
                "total_runtime_s": time.time() - self._t0,
            }
        )


def read_manifest(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL manifest back into its records (all runs)."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def runs_in_manifest(
    records: Sequence[Dict[str, Any]],
) -> Dict[str, List[Dict[str, Any]]]:
    """Group manifest records by ``run_id`` (insertion-ordered)."""
    runs: Dict[str, List[Dict[str, Any]]] = {}
    for rec in records:
        runs.setdefault(rec.get("run_id", "?"), []).append(rec)
    return runs
