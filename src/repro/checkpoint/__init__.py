from repro.checkpoint.ckpt import load_pytree, save_pytree, latest_step

__all__ = ["save_pytree", "load_pytree", "latest_step"]
