from repro.checkpoint.ckpt import load_pytree, save_pytree, latest_step
from repro.checkpoint.trajectory import (
    CheckpointSpec,
    drain_events,
    latest_round,
    load_snapshot,
    save_snapshot,
    segment_bounds,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "latest_step",
    "CheckpointSpec",
    "segment_bounds",
    "save_snapshot",
    "load_snapshot",
    "latest_round",
    "drain_events",
]
