"""npz-based pytree checkpointing with step management, hardened for
preemption.

Layout: ``<dir>/step_<N>.npz`` with leaves flattened to path-keyed
arrays plus a json-encoded dtype manifest (stored inside the npz under a
reserved key) so every leaf round-trips **bit-exactly**:

- dtypes numpy serializes natively (bool / ints / floats / complex) are
  stored as-is;
- extended dtypes numpy's npz format cannot represent (``bfloat16`` and
  friends from ``ml_dtypes``) are packed as raw bytes and re-viewed on
  load, so they neither upcast nor fail.

Writes are preemption-safe: the payload goes to a pid-unique ``.tmp``
sibling, is fsync'd, and lands via atomic ``os.replace``; a killed
writer leaves only ``.tmp`` litter, which ``latest_step`` ignores and
the next ``save_pytree`` sweeps up.  Good enough for the CPU-scale
federated runs; a production TPU deployment would swap in tensorstore
behind the same API.
"""
from __future__ import annotations

import json
import os
import re
import time
import zipfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "|"
# Reserved npz entry holding the json dtype/shape manifest.  The path
# separator makes collision with a real leaf key impossible only if the
# name cannot arise from tree_flatten_with_path -- "__" prefixed and
# suffixed names never do (GetAttrKey renders as the bare field name).
_META_KEY = "__ckpt_meta__"
_TMP_RE = re.compile(r"step_\d+\.npz\.tmp(?:\.(\d+))?$")


def _leaf_keys(tree: Any):
    """(key, leaf) pairs using the stable path-joined key scheme."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves_with_paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out, treedef


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:  # extended dtypes (bfloat16, float8_*) live in ml_dtypes
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))
    except (ImportError, AttributeError) as e:
        raise TypeError(f"cannot resolve checkpoint dtype {name!r}") from e


def _pack(arr: np.ndarray):
    """Return (storable ndarray, meta dict) for one leaf."""
    meta = {"dtype": arr.dtype.name, "shape": list(arr.shape)}
    if arr.dtype.isbuiltin == 1:  # 2 == user-registered (e.g. bfloat16)
        return arr, meta
    # npz would pickle (or reject) extended dtypes; store raw bytes.
    meta["packed"] = 1
    raw = np.frombuffer(np.ascontiguousarray(arr).tobytes(), np.uint8)
    return raw, meta


def _unpack(arr: np.ndarray, meta: Optional[dict]) -> np.ndarray:
    if not meta:
        return arr
    dtype = _resolve_dtype(meta["dtype"])
    if meta.get("packed"):
        arr = np.frombuffer(arr.tobytes(), dtype).reshape(meta["shape"])
    return arr


def _sweep_stale_tmps(directory: str) -> None:
    """Remove ``.tmp`` litter from killed writers (best-effort).

    pid-suffixed tmps belonging to a *live* process are left alone so a
    concurrent writer is never sabotaged.
    """
    try:
        names = os.listdir(directory)
    except OSError:
        return
    for f in names:
        m = _TMP_RE.search(f)
        if not m:
            continue
        pid = m.group(1)
        if pid is not None and int(pid) != os.getpid():
            try:
                os.kill(int(pid), 0)
                continue  # writer still alive; not ours to clean
            except OSError:
                pass  # dead writer
        elif pid is not None:
            continue  # our own in-flight tmp
        try:
            os.remove(os.path.join(directory, f))
        except OSError:
            pass


# Transient-OSError retry policy for save_pytree: shared filesystems
# (NFS, FUSE, overlay mounts on preemptible workers) throw spurious
# EIO/ESTALE under contention; a short bounded exponential backoff rides
# those out without masking a genuinely broken disk.
SAVE_RETRIES = 3
SAVE_BACKOFF_S = 0.1


def save_pytree(
    directory: str,
    tree: Any,
    step: int,
    *,
    retries: int = SAVE_RETRIES,
    backoff_s: float = SAVE_BACKOFF_S,
) -> str:
    """Atomically persist ``tree`` as ``<directory>/step_<step>.npz``.

    Transient ``OSError`` during the write/fsync/rename is retried up to
    ``retries`` times with exponential backoff (``backoff_s * 2**attempt``
    seconds); each attempt rewrites the tmp sibling from scratch, so a
    half-written file is never renamed in.  After the final attempt the
    original error propagates, chained under a message naming the path.
    """
    os.makedirs(directory, exist_ok=True)
    _sweep_stale_tmps(directory)
    pairs, _ = _leaf_keys(tree)
    flat, meta = {}, {}
    for key, leaf in pairs:
        if key == _META_KEY:
            raise ValueError(f"leaf key collides with reserved {_META_KEY!r}")
        arr, m = _pack(np.asarray(leaf))
        flat[key] = arr
        meta[key] = m
    flat[_META_KEY] = np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = f"{path}.tmp.{os.getpid()}"
    for attempt in range(retries + 1):
        try:
            try:
                with open(tmp, "wb") as f:
                    np.savez(f, **flat)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):  # failed mid-write; no litter
                    try:
                        os.remove(tmp)
                    except OSError:
                        pass
            break
        except OSError as e:
            if attempt == retries:
                raise OSError(
                    f"save_pytree: writing {path!r} failed "
                    f"{retries + 1} times (last: {e}); check the snapshot "
                    f"filesystem"
                ) from e
            time.sleep(backoff_s * (2 ** attempt))
    try:  # make the rename durable too (best-effort on odd filesystems)
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass
    return path


def _leaf_shape_dtype(leaf: Any):
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is None or dtype is None:  # python scalars / lists
        as_np = np.asarray(leaf)
        shape, dtype = as_np.shape, as_np.dtype
    return tuple(shape), np.dtype(dtype)


def load_pytree(
    directory: str, like: Any, step: Optional[int] = None
) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match).

    ``like`` leaves only need ``.shape``/``.dtype`` -- concrete arrays
    and ``jax.ShapeDtypeStruct`` templates both work.  When the
    checkpoint carries a dtype manifest (everything written by this
    version), leaves are restored bit-exactly and a dtype mismatch with
    ``like`` is an error rather than a silent cast; manifest-less legacy
    files keep the old cast-to-like behavior.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            detail = (
                "directory does not exist"
                if not os.path.isdir(directory)
                else "directory has no committed step_<N>.npz files"
            )
            raise FileNotFoundError(
                f"no checkpoints in {directory!r} ({detail}); point "
                f"resume_from at a directory written by save_snapshot/"
                f"save_pytree, or start a fresh run without resume_from"
            )
    path = os.path.join(directory, f"step_{step:08d}.npz")
    if not os.path.exists(path):
        committed = latest_step(directory)
        raise FileNotFoundError(
            f"checkpoint {path!r} does not exist"
            + (
                f"; latest committed step in {directory!r} is {committed}"
                if committed is not None
                else f"; {directory!r} has no committed snapshots"
            )
        )
    try:
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
    except (zipfile.BadZipFile, OSError, ValueError, EOFError) as e:
        raise ValueError(
            f"checkpoint {path!r} is unreadable ({type(e).__name__}: {e}); "
            f"the file is corrupt or torn — delete it and resume from an "
            f"earlier committed step"
        ) from e
    meta = None
    if _META_KEY in flat:
        meta = json.loads(flat.pop(_META_KEY).tobytes().decode("utf-8"))
    pairs, treedef = _leaf_keys(like)
    missing = {k for k, _ in pairs} - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    new_leaves = []
    for key, leaf in pairs:
        arr = _unpack(flat[key], meta.get(key) if meta else None)
        shape, dtype = _leaf_shape_dtype(leaf)
        if arr.shape != shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {shape}")
        if meta is not None:
            if arr.dtype != dtype:
                raise ValueError(
                    f"dtype mismatch at {key}: checkpoint has {arr.dtype}, "
                    f"template wants {dtype}"
                )
            new_leaves.append(jax.numpy.asarray(arr))
        else:
            new_leaves.append(jax.numpy.asarray(arr, dtype=dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def latest_step(directory: str) -> Optional[int]:
    """Largest committed step, ignoring ``.tmp`` litter from killed writers.

    Only fully-renamed ``step_<N>.npz`` files match; an interrupted
    writer's ``step_<N>.npz.tmp.<pid>`` never does, so a resume cannot
    pick up a torn file.
    """
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
