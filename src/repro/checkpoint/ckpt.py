"""Minimal npz-based pytree checkpointing with step management.

Layout: <dir>/step_<N>.npz with leaves flattened to path-keyed arrays
plus a json-encoded treedef for faithful restoration (lists/dicts/
namedtuple-as-dict).  Good enough for the CPU-scale federated runs; a
production TPU deployment would swap in tensorstore behind the same API.
"""
from __future__ import annotations

import io
import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "|"


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save_pytree(directory: str, tree: Any, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def load_pytree(
    directory: str, like: Any, step: Optional[int] = None
) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    ref_flat = _flatten(like)
    missing = set(ref_flat) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for lpath, leaf in leaves_with_paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in lpath
        )
        arr = flat[key]
        if arr.shape != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
