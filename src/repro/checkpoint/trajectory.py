"""Trajectory checkpointing: spec, snapshot IO, and event recording.

:class:`CheckpointSpec` is the user-facing knob threaded through
``OceanConfig`` / ``Scenario`` / ``GridEngine`` as a must-agree static.
It carries *where* snapshots land and *how often* (in Alg. 1 rounds) a
segment boundary is committed.  A ``None`` spec everywhere keeps the
legacy single-program execution paths byte-identical.

Snapshots are plain pytrees persisted through the hardened
:mod:`repro.checkpoint.ckpt` (atomic replace, bit-exact dtypes), keyed
by the *global round index* already executed: ``step_r`` holds the state
needed to run rounds ``r..T``.  Save/restore events are recorded into a
module-global :class:`CheckpointEventRecorder` (mirroring
``repro.obs.spans.SPANS``) that ``benchmarks/run.py`` drains into the
JSONL run manifest.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import ckpt

__all__ = [
    "CheckpointSpec",
    "CheckpointEventRecorder",
    "CKPT_EVENTS",
    "record_event",
    "drain_events",
    "segment_bounds",
    "save_snapshot",
    "load_snapshot",
    "latest_round",
]


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """Where and how often to snapshot a segmented trajectory.

    ``directory``   — snapshot directory (created on first save).
    ``every_rounds``— segment length: one ``lax.scan`` / fused-kernel
                      launch per segment, snapshot at each boundary.

    Frozen + hashable so it can ride jit statics and the engine's
    must-agree compatibility check.
    """

    directory: str
    every_rounds: int

    def __post_init__(self) -> None:
        if not self.directory:
            raise ValueError("CheckpointSpec.directory must be non-empty")
        if int(self.every_rounds) < 1:
            raise ValueError(
                f"CheckpointSpec.every_rounds must be >= 1, got {self.every_rounds}"
            )
        object.__setattr__(self, "every_rounds", int(self.every_rounds))

    def to_dict(self) -> Dict[str, Any]:
        return {"directory": self.directory, "every_rounds": self.every_rounds}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CheckpointSpec":
        return cls(directory=d["directory"], every_rounds=int(d["every_rounds"]))


def segment_bounds(
    num_rounds: int, every_rounds: int, start: int = 0
) -> List[Tuple[int, int]]:
    """Half-open ``(t0, t1)`` segment bounds covering ``[start, num_rounds)``.

    Boundaries stay aligned to multiples of ``every_rounds`` regardless
    of ``start``, so a resumed run re-enters the same segment grid as
    the uninterrupted one (a prerequisite for bitwise identity).
    """
    if not 0 <= start <= num_rounds:
        raise ValueError(f"start {start} outside [0, {num_rounds}]")
    bounds = []
    t0 = start
    while t0 < num_rounds:
        t1 = min(((t0 // every_rounds) + 1) * every_rounds, num_rounds)
        bounds.append((t0, t1))
        t0 = t1
    return bounds


class CheckpointEventRecorder:
    """Accumulates checkpoint save/restore events (manifest-ready rows)."""

    def __init__(self) -> None:
        self._events: List[Dict[str, Any]] = []

    def record(self, kind: str, **fields: Any) -> None:
        row = {"kind": kind, "time": time.time()}
        row.update(fields)
        self._events.append(row)

    def drain(self) -> List[Dict[str, Any]]:
        out, self._events = self._events, []
        return out

    def snapshot(self) -> Tuple[Dict[str, Any], ...]:
        return tuple(dict(e) for e in self._events)


CKPT_EVENTS = CheckpointEventRecorder()


def record_event(kind: str, **fields: Any) -> None:
    CKPT_EVENTS.record(kind, **fields)


def drain_events() -> List[Dict[str, Any]]:
    return CKPT_EVENTS.drain()


def save_snapshot(spec: CheckpointSpec, snapshot: Any, round_idx: int) -> str:
    """Persist ``snapshot`` at global round ``round_idx`` (atomic)."""
    path = ckpt.save_pytree(spec.directory, snapshot, round_idx)
    record_event("save", directory=spec.directory, round=int(round_idx), path=path)
    return path


def load_snapshot(
    directory: str, like: Any, round_idx: Optional[int] = None
) -> Tuple[Any, int]:
    """Restore the snapshot at ``round_idx`` (default: latest committed)."""
    snap, step = ckpt.load_pytree(directory, like, round_idx)
    record_event("restore", directory=directory, round=int(step))
    return snap, step


def latest_round(directory: str) -> Optional[int]:
    """Latest committed snapshot round in ``directory`` (None if empty)."""
    return ckpt.latest_step(directory)
